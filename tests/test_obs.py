"""Observability spine: bounded streaming histograms (O(1)-memory soak
regression), registry get-or-create semantics, Prometheus/JSON/trace
exporters, non-blocking stats snapshots, span integrity on EVERY
runtime failure path (queue shed, deadline shed, KV OOM, chunk-local
fault, close), the no-op disabled mode, and the online recall auditor
against an offline brute-force rerank."""

import json
import threading
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.lss import LSSConfig
from repro.data.synthetic import lm_dataset
from repro.models import transformer as T
from repro.obs.audit import RecallAuditor
from repro.obs.export import MetricsServer, prometheus_text
from repro.obs.metrics import NOOP_METRIC
from repro.obs.tracing import NOOP_SPAN
from repro.serve import (AsyncRuntime, DeadlineExceededError, Engine,
                         KVPoolExhaustedError, LMDecoder, RuntimeClosedError)
from tools.check_metrics import parse_exposition


@pytest.fixture(autouse=True)
def _span_hygiene():
    """Every test starts with a clean trace ring and must leave no span
    open — the span-leak regression for every failure path below."""
    obs.reset_tracer()
    yield
    obs.assert_quiescent()
    obs.reset_tracer()


def _engine(m=512, d=32, top_k=5, buckets=(8,), audit_rate=None):
    w = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    eng = Engine(None, w, None, LSSConfig(k_bits=4, n_tables=2),
                 top_k=top_k, head="lss", buckets=buckets,
                 audit_rate=audit_rate)
    eng.fit_random(jax.random.PRNGKey(1))
    return eng


# -------------------------------------------------------------- metrics --

def test_histogram_quantiles_exact_under_reservoir_cap():
    h = obs.Histogram("h_exact")
    rng = np.random.default_rng(0)
    vals = rng.lognormal(0.0, 2.0, size=1000)
    for v in vals:
        h.record(v)
    assert h.count == 1000
    assert h.quantile(50) == np.percentile(vals, 50)
    p50, p95, p99 = h.quantile((50, 95, 99))
    assert (p50, p95, p99) == tuple(np.percentile(vals, (50, 95, 99)))
    assert p50 <= p95 <= p99
    assert h.mean() == pytest.approx(vals.mean())


def test_histogram_empty_and_edge_values():
    h = obs.Histogram("h_edge")
    assert np.isnan(h.quantile(50)) and np.isnan(h.mean())
    assert all(np.isnan(v) for v in h.quantile((50, 99)))
    h.record(0.0)                       # non-positive -> first bucket
    h.record(-3.0)
    h.record(1e12)                      # beyond hi -> +inf bucket
    assert h.count == 3
    snap = h.bucket_snapshot()
    assert snap[0][1] == 2 and snap[-1] == (float("inf"), 3)


def test_soak_bounded_memory():
    """200k records must not grow the histogram past its construction
    footprint, and 3x the trace cap of spans must not grow the ring —
    the O(1)-memory regression for week-long serving windows."""
    h = obs.Histogram("h_soak", reservoir=512)
    n_buckets = len(h.bounds)
    rng = np.random.default_rng(1)
    for v in rng.lognormal(0.0, 3.0, size=200_000):
        h.record(v)
    assert h.count == 200_000
    assert len(h.sample()) == 512               # reservoir pinned at cap
    assert len(h.bounds) == n_buckets           # bucket grid never grows
    assert h.bucket_snapshot()[-1][1] == 200_000
    q = h.quantile((50, 95, 99))                # still unbiased + ordered
    assert all(np.isfinite(q)) and q[0] <= q[1] <= q[2]

    for i in range(3 * 4096):
        obs.start_span("soak", i=i).end()
    events = obs.trace_export()["traceEvents"]
    assert len(events) <= 4096                  # ring held its cap


def test_registry_get_or_create_and_type_mismatch():
    reg = obs.MetricsRegistry("t0", enabled=True)
    c = reg.counter("hits", "help text")
    assert reg.counter("hits") is c
    c.inc(), c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(TypeError):
        reg.gauge("hits")
    g = reg.gauge("depth")
    g.set(7)
    assert g.value == 7.0
    assert reg in obs.all_registries()


def test_registry_snapshot_collectors_and_reset():
    reg = obs.MetricsRegistry("t1", enabled=True)
    reg.counter("n").inc(4)
    reg.histogram("lat").record(0.5)
    reg.collect(lambda r: r.gauge("live").set(42.0))
    snap = reg.snapshot()
    assert snap["scope"] == "t1"
    assert snap["metrics"]["n"] == {"type": "counter", "value": 4.0}
    assert snap["metrics"]["live"]["value"] == 42.0
    assert snap["metrics"]["lat"]["count"] == 1
    json.dumps(snap)                            # JSON-ready by contract
    reg.reset()
    assert reg.counter("n").value == 0.0
    assert reg.histogram("lat").count == 0


def test_noop_mode_hands_out_shared_stubs():
    prev = obs.enabled()
    obs.set_enabled(False)
    try:
        reg = obs.MetricsRegistry("off")
        assert reg.counter("c") is NOOP_METRIC
        assert reg.histogram("h") is NOOP_METRIC
        NOOP_METRIC.inc(), NOOP_METRIC.record(1.0), NOOP_METRIC.set(2.0)
        assert np.isnan(NOOP_METRIC.quantile(50))
        assert reg not in obs.all_registries()
        span = obs.start_span("s")
        assert span is NOOP_SPAN
        span.event("e"), span.end()
        obs.event("instant")                    # swallowed, not recorded
        assert obs.trace_export()["traceEvents"] == []
    finally:
        obs.set_enabled(prev)


# ------------------------------------------------------------ exporters --

def test_prometheus_text_is_valid_exposition():
    reg = obs.MetricsRegistry("promtest", enabled=True)
    reg.counter("ptest_requests_total", "served").inc(3)
    reg.gauge("ptest_depth", "queue depth").set(2)
    h = reg.histogram("ptest_lat_seconds", "latency")
    for v in (0.001, 0.01, 0.1, 1.0, 10.0):
        h.record(v)
    text = prometheus_text([reg])
    families, errors = parse_exposition(text)
    assert errors == []
    assert families["ptest_requests_total"]["type"] == "counter"
    assert families["ptest_lat_seconds"]["type"] == "histogram"
    buckets = [(n, lab, v) for n, lab, v
               in families["ptest_lat_seconds"]["samples"]
               if n.endswith("_bucket")]
    counts = [v for _, _, v in buckets]
    assert counts == sorted(counts)             # cumulative + monotone
    assert counts[-1] == 5.0
    assert 'scope="promtest"' in text


def test_metrics_server_routes():
    reg = obs.MetricsRegistry("srvtest", enabled=True)
    reg.counter("srv_up").inc()
    with MetricsServer(port=0) as srv:
        base = f"http://{srv.host}:{srv.port}"
        with urllib.request.urlopen(srv.url) as r:
            assert r.status == 200
            body = r.read().decode()
        assert "srv_up" in body
        assert parse_exposition(body)[1] == []
        with urllib.request.urlopen(base + "/metrics.json") as r:
            snap = json.load(r)
        assert any(s.get("scope") == "srvtest" for s in snap["registries"])
        with urllib.request.urlopen(base + "/trace") as r:
            assert "traceEvents" in json.load(r)
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope")


def test_trace_export_chrome_format(tmp_path):
    s = obs.start_span("outer", rid=1)
    s.event("mark", detail="x")
    s.end("ok", extra=2)
    obs.event("global_instant", pid=3)
    hung = obs.start_span("hung")
    out = obs.trace_export(str(tmp_path / "trace.json"))
    hung.end("error")                           # close before teardown
    on_disk = json.loads((tmp_path / "trace.json").read_text())
    assert out["traceEvents"] == on_disk["traceEvents"]
    by_ph = {}
    for ev in out["traceEvents"]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    [outer] = [e for e in by_ph["X"] if e["name"] == "outer"]
    assert outer["args"] == {"rid": 1, "extra": 2, "status": "ok"}
    assert outer["dur"] >= 0
    names = {e["name"] for e in by_ph["i"]}
    assert {"outer.mark", "global_instant"} <= names
    assert [e["name"] for e in by_ph["B"]] == ["hung"]


# -------------------------------------------- non-blocking stats snapshot --

def _held(lock) -> bool:
    """Is the lock held (Lock) / held by this thread (RLock)?"""
    if hasattr(lock, "locked"):
        return lock.locked()
    return lock._is_owned()


class _QuantileSpy:
    """Histogram wrapper that records whether a lock was held when
    quantile math ran — pinning the 'percentiles outside the component
    lock' contract without timing assumptions."""

    def __init__(self, h, lock):
        self._h, self._lock = h, lock
        self.locked_during: list[bool] = []

    def __getattr__(self, name):
        return getattr(self._h, name)

    def quantile(self, q):
        self.locked_during.append(_held(self._lock))
        return self._h.quantile(q)

    def mean(self):
        self.locked_during.append(_held(self._lock))
        return self._h.mean()


def test_stats_quantiles_run_outside_locks():
    eng = _engine()
    with AsyncRuntime(eng) as rt:
        for _ in range(8):
            rt.submit(np.zeros(32, np.float32))
        rt.drain(timeout=60.0)
        lat_spy = _QuantileSpy(rt._h_lat, rt._mu)
        dev_spy = _QuantileSpy(rt._h_device, rt._mu)
        rt._h_lat, rt._h_device = lat_spy, dev_spy
        s = rt.stats()
        rt._h_lat, rt._h_device = lat_spy._h, dev_spy._h
    assert s.latency_p50_ms > 0
    assert lat_spy.locked_during == [False]     # p50/p95/p99: one call
    assert dev_spy.locked_during == [False]

    espy = _QuantileSpy(eng._h_lat, eng.lock)
    eng._h_lat = espy
    m = eng.metrics()
    eng._h_lat = espy._h
    assert m.n_requests == 8
    assert espy.locked_during == [False]


# ------------------------------------------------ span integrity: sheds --

def test_queue_shed_spans_end_with_shed_queue():
    eng = _engine()
    rt = AsyncRuntime(eng, max_queue=2, policy="shed", start=False)
    futs = [rt.submit(np.zeros(32, np.float32)) for _ in range(5)]
    shed = [f for f in futs if f.done()]
    assert len(shed) == 3
    assert all(f.span.status == "shed_queue" for f in shed)
    rt.start()
    rt.drain(timeout=60.0)
    rt.close()
    served = [f for f in futs if f not in shed]
    assert all(f.span.status == "ok" for f in served)


def test_deadline_shed_spans_end_with_shed_deadline():
    eng = _engine()
    rt = AsyncRuntime(eng, start=False)
    futs = [rt.submit(np.zeros(32, np.float32), deadline_s=0.01)
            for _ in range(3)]
    time.sleep(0.05)
    rt.start()
    rt.drain(timeout=60.0)
    rt.close()
    for f in futs:
        assert isinstance(f.exception(5.0), DeadlineExceededError)
        assert f.span.status == "shed_deadline"


def test_close_fails_pending_spans_with_closed():
    eng = _engine()
    rt = AsyncRuntime(eng, start=False)
    f = rt.submit(np.zeros(32, np.float32))
    rt.close()
    assert isinstance(f.exception(5.0), RuntimeClosedError)
    assert f.span.status == "closed"


def test_chunk_fault_spans_end_with_error_and_isolate():
    eng = _engine(buckets=(8,))
    with AsyncRuntime(eng) as rt:
        bad = rt.submit(np.zeros(33, np.float32))    # d=33 != 32
        assert bad.exception(timeout=60.0) is not None
        good = rt.submit(np.zeros(32, np.float32))
        assert good.result(timeout=60.0) is not None
    assert bad.span.status == "error"
    assert good.span.status == "ok"
    chunk_status = [e["args"]["status"]
                    for e in obs.trace_export()["traceEvents"]
                    if e["ph"] == "X" and e["name"] == "chunk"]
    assert "error" in chunk_status and "ok" in chunk_status


def test_kv_oom_shed_span_and_event():
    """A decode session starved at a page boundary fails with
    KVPoolExhaustedError: its decode_session span must end shed_kv_oom,
    the survivor's must end ok, and the shed_kv_oom instant event must
    land in the trace."""
    cfg = T.TransformerConfig(name="tp-obs", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=2, head_dim=16,
                              d_ff=64, vocab=256, dtype=jnp.float32,
                              kv_chunk=32)
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    toks = np.asarray(lm_dataset(0, 8 * 17, 256, 17))
    dec = LMDecoder(params, cfg, max_streams=2, max_len=16,
                    kv_layout="paged", kv_page_tokens=4, kv_pages=4)
    sched = dec.scheduler(head="full")
    rt = AsyncRuntime(dec.engine, scheduler=sched, start=False)
    starved = rt.submit_decode(toks[0, :3], max_new_tokens=10)
    survivor = rt.submit_decode(toks[1, :5], max_new_tokens=2)
    rt.start()
    rt.drain(timeout=120.0)
    rt.close()
    assert isinstance(starved.exception(), KVPoolExhaustedError)
    assert starved.span.status == "shed_kv_oom"
    assert survivor.finish_reason == "max_tokens"
    assert survivor.span.status == "ok"
    oom_events = [e for e in obs.trace_export()["traceEvents"]
                  if e["name"] == "shed_kv_oom"]
    assert oom_events


# --------------------------------------------------------- recall audit --

def test_audit_recall_matches_offline_brute_force_exactly():
    """At rate 1.0 the auditor's cumulative recall must EQUAL the
    offline brute-force recall of the same served traffic (integer
    hit accumulation, not a sampling estimate)."""
    eng = _engine(buckets=(8,), audit_rate=1.0)
    assert eng.auditor is not None and eng.auditor.rate == 1.0
    rng = np.random.default_rng(7)
    xs = rng.standard_normal((32, 32)).astype(np.float32)
    for i in range(0, 32, 8):
        eng.rank(xs[i:i + 8], head="lss", record=True)
    eng.auditor.drain()
    online = eng.auditor.recall
    assert eng.auditor.n_rows == 32
    eng.auditor.close()

    hits = total = 0
    for i in range(0, 32, 8):
        x = xs[i:i + 8]
        served = np.asarray(eng.rank(x, head="lss", record=False).ids)
        exact = np.asarray(eng.rank(x, head="full", record=False).ids)
        hit = (exact[:, :, None] == served[:, None, :]).any(-1)
        hits, total = hits + int(hit.sum()), total + hit.size
    assert abs(online - hits / total) < 1e-6


def test_audit_never_audits_exact_head_traffic():
    eng = _engine(buckets=(8,), audit_rate=1.0)
    eng.rank(np.zeros((8, 32), np.float32), head="full", record=True)
    eng.auditor.drain()
    assert eng.auditor.n_rows == 0              # full head needs no audit
    eng.auditor.close()


def test_audit_backlog_bounded_drops_count_as_staleness():
    """A full audit queue sheds the sample (serving never blocks) and
    counts it on the staleness counter."""
    gate = threading.Event()

    class _SlowEngine:
        def rank(self, x, head="full", record=False):
            gate.wait(timeout=10.0)

            class Out:
                ids = np.zeros((1, 2), np.int64)
            return Out()

    reg = obs.MetricsRegistry("audittest", enabled=True)
    aud = RecallAuditor(_SlowEngine(), 1.0, queue_cap=1, registry=reg)
    row = (np.zeros((1, 4), np.float32), np.zeros((1, 2), np.int64))
    assert aud.offer(*row)                      # worker takes it, blocks
    deadline = time.monotonic() + 5.0
    while aud._q.qsize() and time.monotonic() < deadline:
        time.sleep(0.005)                       # wait for the dequeue
    assert aud.offer(*row)                      # refills the cap-1 queue
    assert not aud.offer(*row)                  # full -> shed, not block
    assert reg.counter("lss_audit_dropped_total").value == 1.0
    gate.set()
    aud.drain()
    aud.close()
    assert aud.n_rows == 2
    assert reg.counter("lss_audit_rows_total").value == 2.0


def test_audit_offer_thunk_only_materialized_when_sampled():
    calls = []

    class _NullEngine:
        def rank(self, x, head="full", record=False):
            class Out:
                ids = np.zeros((1, 2), np.int64)
            return Out()

    reg = obs.MetricsRegistry("thunktest", enabled=True)
    aud = RecallAuditor(_NullEngine(), 0.0, registry=reg)
    aud.offer(lambda: calls.append(1), np.zeros((1, 2), np.int64))
    assert calls == []                          # rate 0: thunk never runs
    aud.close()
    aud2 = RecallAuditor(_NullEngine(), 1.0, registry=reg, seed=1)
    aud2.offer(lambda: (calls.append(1),
                        np.zeros((1, 4), np.float32))[1],
               np.zeros((1, 2), np.int64))
    aud2.drain()
    aud2.close()
    assert calls == [1]                         # rate 1: materialized once
