"""IUL learning mechanism: mining vs naive, loss behavior, end-to-end
recall gain on structured data (the paper's core claim, small scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import iul, simhash
from repro.core.lss import LSSConfig, build_index, label_recall, retrieve


def test_mine_pairs_matches_naive():
    key = jax.random.PRNGKey(0)
    m, d, n = 100, 8, 16
    w = jax.random.normal(key, (m, d))
    q = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    labels = jax.random.randint(jax.random.PRNGKey(2), (n, 3), -1, m)
    cfg = LSSConfig(k_bits=3, n_tables=2)
    w_aug = simhash.augment_neurons(w, None)
    q_aug = simhash.augment_queries(q)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(3), d + 1, 3, 2)
    index = build_index(w_aug, theta, cfg)
    t1, t2 = jnp.asarray(0.5), jnp.asarray(-0.5)
    pairs = iul.mine_pairs(q_aug, labels, w_aug, index, t1, t2)

    cand, _ = retrieve(q_aug, index)
    candn, labn = np.asarray(cand), np.asarray(labels)
    ip = np.asarray(q_aug @ w_aug.T)
    pos = np.asarray(pairs.pos_mask)
    neg = np.asarray(pairs.neg_mask)
    for i in range(n):
        s = set(x for x in candn[i] if x >= 0)
        for j, y in enumerate(labn[i]):
            want = y >= 0 and y not in s and ip[i, y] > 0.5
            assert bool(pos[i, j]) == want, (i, j)
        labset = set(x for x in labn[i] if x >= 0)
        for c_idx, cid in enumerate(candn[i]):
            want = cid >= 0 and cid not in labset and ip[i, cid] < -0.5
            assert bool(neg[i, c_idx]) == want, (i, c_idx)


def test_iul_loss_decreases_and_separates():
    """200 steps on one pair batch must raise positive collisions and
    suppress negative ones (the single-batch convergence experiment)."""
    from repro.optim import adamw_init, adamw_update
    key = jax.random.PRNGKey(0)
    d, m, n = 32, 500, 128
    w = jax.random.normal(key, (m, d))
    y = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, m)
    q = 0.9 * w[y] + 0.4 * jax.random.normal(jax.random.PRNGKey(2), (n, d))
    labels = y[:, None]
    cfg = LSSConfig(k_bits=4, n_tables=1)
    w_aug = simhash.augment_neurons(w, None)
    q_aug = simhash.augment_queries(q)
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(3), d + 1, 4, 1)
    index = build_index(w_aug, theta, cfg)
    t1, t2 = iul.calibrate_thresholds(q_aug, w_aug, labels, cfg)
    pairs = iul.mine_pairs(q_aug, labels, w_aug, index, t1, t2)
    opt = adamw_init(theta)
    lossg = jax.jit(jax.value_and_grad(iul.iul_loss))
    l0 = None
    cp0, cn0 = iul.collision_prob(theta, q_aug, w_aug, pairs, 4, 1)
    for i in range(150):
        l, g = lossg(theta, q_aug, w_aug, pairs)
        if l0 is None:
            l0 = float(l)
        theta, opt = adamw_update(g, opt, theta, lr=0.02)
    cp1, cn1 = iul.collision_prob(theta, q_aug, w_aug, pairs, 4, 1)
    assert float(l) < l0 * 0.8
    assert float(cp1) > float(cp0) + 0.2         # positives pulled in
    assert float(cn1) < float(cn0) - 0.2         # negatives pushed out


@pytest.mark.slow
def test_fit_lss_beats_random_hash_on_structured_data():
    """Paper §4.2: the learned index must retrieve labels better than
    random SimHash at the same sample size (topic-structured data)."""
    key = jax.random.PRNGKey(0)
    d, m, n, T = 32, 1000, 768, 24
    kc, kt, kw, kq, kl = jax.random.split(key, 5)
    cent = jax.random.normal(kc, (T, d))
    topic = jax.random.randint(kt, (m,), 0, T)
    w = cent[topic] + 0.45 * jax.random.normal(kw, (m, d))
    y = jax.random.randint(kl, (n,), 0, m)
    q = cent[topic[y]] + 0.3 * jax.random.normal(kq, (n, d)) + 0.3 * w[y]
    labels = y[:, None]
    cfg = LSSConfig(k_bits=4, n_tables=1, iul_epochs=8, iul_batch=256,
                    iul_lr=0.02, iul_inner_steps=10)
    q_aug = simhash.augment_queries(q)
    # random-hash baseline (SLIDE)
    theta0 = simhash.init_hyperplanes(jax.random.PRNGKey(9), d + 1, 4, 1)
    idx0 = build_index(simhash.augment_neurons(w, None), theta0, cfg)
    cand0, _ = retrieve(q_aug, idx0)
    rec0 = float(label_recall(cand0, labels))
    index, hist = iul.fit_lss(jax.random.PRNGKey(1), q, labels, w, None, cfg)
    cand1, _ = retrieve(q_aug, index)
    rec1 = float(label_recall(cand1, labels))
    assert rec1 > rec0 + 0.05, (rec0, rec1, hist["recall"])
