"""Deterministic fallback for ``hypothesis`` when it is not installed.

The image pins the runtime deps only; ``hypothesis`` lives in the ``dev``
extra.  When it is absent, tests that use ``@given`` still run — against a
fixed seeded sweep (endpoints first, then pseudo-random draws) instead of
hypothesis' adaptive search.  With the real package installed (CI does
``pip install -e .[dev]``), this module is never imported.

Only the surface the test suite uses is implemented: ``given``,
``settings(max_examples=, deadline=)``, ``strategies.integers`` and
``strategies.floats``.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    """A strategy is (endpoint examples, pseudo-random generator)."""

    def __init__(self, endpoints, gen):
        self.endpoints = endpoints
        self.gen = gen


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        (int(min_value), int(max_value)),
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_) -> _Strategy:
    return _Strategy(
        (float(min_value), float(max_value)),
        lambda rng: float(rng.uniform(min_value, max_value)))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for i in range(max(n, 2)):
                drawn = tuple(
                    s.endpoints[i] if i < 2 else s.gen(rng)
                    for s in strategies)
                fn(*args, *drawn, **kwargs)
        # the drawn params must not look like pytest fixtures
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
