"""GCN vs dense-adjacency oracle, sampler validity, recsys components."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import graph_dataset, to_csr
from repro.models import gnn, recsys


def test_gcn_matches_dense_adjacency():
    cfg = gnn.GCNConfig(name="t", n_layers=2, d_feat=8, d_hidden=16,
                        n_classes=4)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    n, e = 30, 80
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    edges = jax.random.randint(jax.random.PRNGKey(2), (e, 2), 0, n)
    out = gnn.forward(params, x, edges, cfg)
    A = jnp.zeros((n, n)).at[edges[:, 1], edges[:, 0]].add(1.0)
    deg = A.sum(1) + 1
    dn = jnp.diag(deg ** -0.5)
    ah = dn @ (A + jnp.eye(n)) @ dn
    h = x
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = ah @ h @ w + b
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_gcn_padding_invariance():
    """-1 padded edges must not change the result on real nodes."""
    cfg = gnn.GCNConfig(name="t", n_layers=2, d_feat=4, d_hidden=8,
                        n_classes=3)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 4))
    edges = jax.random.randint(jax.random.PRNGKey(2), (20, 2), 0, 10)
    out1 = gnn.forward(params, x, edges, cfg)
    padded = jnp.concatenate([edges, jnp.full((7, 2), -1, jnp.int32)])
    out2 = gnn.forward(params, x, padded, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_neighbor_sampler_validity(rng):
    g = graph_dataset(0, n_nodes=200, n_edges=1000, d_feat=4, n_classes=5)
    indptr, indices = to_csr(g["edges"], 200)
    seeds = jnp.asarray(rng.integers(0, 200, size=16).astype(np.int32))
    nbrs, edges = gnn.sample_block(jax.random.PRNGKey(0),
                                   jnp.asarray(indptr),
                                   jnp.asarray(indices), seeds, 5)
    nbrs = np.asarray(nbrs)
    ip, ix = np.asarray(indptr), np.asarray(indices)
    for i, s in enumerate(np.asarray(seeds)):
        actual = set(ix[ip[s]:ip[s + 1]].tolist()) | {int(s)}
        assert set(nbrs[i].tolist()) <= actual


def test_embedding_bag_modes():
    table = jnp.arange(20.0).reshape(10, 2)
    ids = jnp.array([[0, 1, -1], [5, -1, -1]])
    s = recsys.embedding_bag(table, ids, "sum")
    np.testing.assert_allclose(np.asarray(s), [[2, 4], [10, 11]])
    m = recsys.embedding_bag(table, ids, "mean")
    np.testing.assert_allclose(np.asarray(m), [[1, 2], [10, 11]])
    mx = recsys.embedding_bag(table, ids, "max")
    np.testing.assert_allclose(np.asarray(mx), [[2, 3], [10, 11]])


def test_fm_identity():
    """FM trick 0.5*((Σv)² − Σv²) == Σ_{i<j} <v_i, v_j>."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(5, 8))
    want = sum(v[i] @ v[j] for i in range(5) for j in range(i + 1, 5))
    s = v.sum(0)
    got = 0.5 * ((s ** 2).sum() - (v ** 2).sum())
    assert abs(want - got) < 1e-9


def test_augru_attention_gating():
    """AUGRU with zero attention must keep the initial (zero) state."""
    cfg = recsys.CTRConfig(name="t", kind="dien", n_fields=1,
                           vocab_per_field=50, embed_dim=4, seq_len=6,
                           gru_dim=8, mlp_dims=(8,))
    params = recsys.init_dien(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))
    h_zero = recsys._gru_scan(x, params["augru"], 8,
                              att=jnp.zeros((2, 6)))
    assert float(jnp.abs(h_zero).max()) == 0.0
    h_one = recsys._gru_scan(x, params["augru"], 8,
                             att=jnp.ones((2, 6)))
    assert float(jnp.abs(h_one).max()) > 0.0
    # unrolled == scanned
    h_unroll = recsys._gru_scan(x, params["augru"], 8,
                                att=jnp.ones((2, 6)), unroll=True)
    np.testing.assert_allclose(np.asarray(h_one), np.asarray(h_unroll),
                               rtol=1e-5, atol=1e-6)


def test_bert4rec_masking_semantics():
    cfg = recsys.Bert4RecConfig(name="t", n_items=100, embed_dim=16,
                                n_blocks=1, n_heads=2, seq_len=8)
    params = recsys.init_bert4rec(jax.random.PRNGKey(0), cfg)
    seq = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 100)
    # padded positions must not affect other positions' hidden states
    seq_pad = seq.at[:, -2:].set(-1)
    h1 = recsys.bert4rec_encode(params, seq_pad, cfg)
    seq_pad2 = seq.at[:, -2:].set(-1).at[:, -1].set(-1)
    h2 = recsys.bert4rec_encode(params, seq_pad2, cfg)
    np.testing.assert_allclose(np.asarray(h1[:, :6]), np.asarray(h2[:, :6]),
                               rtol=1e-4, atol=1e-5)
