"""Bucket-major table construction vs a naive Python dict-of-lists."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import simhash
from repro.core.tables import bucket_load_stats, build_tables, \
    bucketize_weights


def _naive_tables(buckets: np.ndarray, n_buckets: int, cap: int):
    """buckets: [m, L] -> list of L dicts bucket->list(neurons), truncated
    in first-come order (matches the stable-sort build)."""
    m, l = buckets.shape
    out = []
    for t in range(l):
        d = {b: [] for b in range(n_buckets)}
        for i in range(m):
            d[int(buckets[i, t])].append(i)
        out.append({b: v[:cap] for b, v in d.items()})
    return out


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(20, 200),
       st.integers(2, 17))
def test_table_matches_naive(k, l, m, cap):
    key = jax.random.PRNGKey(m)
    w = jax.random.normal(key, (m, 8))
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), 8, k, l)
    tables = build_tables(w, theta, k, l, cap)
    buckets = np.asarray(simhash.bucket_ids(w, theta, k, l))
    naive = _naive_tables(buckets, 2 ** k, cap)
    ids = np.asarray(tables.table_ids)
    for t in range(l):
        for b in range(2 ** k):
            got = sorted(x for x in ids[t, b] if x >= 0)
            assert got == sorted(naive[t][b]), (t, b)
    # every neuron appears at most once per table; drops accounted
    for t in range(l):
        flat = ids[t][ids[t] >= 0]
        assert len(flat) == len(set(flat.tolist()))
        assert len(flat) + int(tables.n_dropped[t]) == m


def test_bucketize_weights_layout():
    key = jax.random.PRNGKey(0)
    m, d = 50, 8
    w = jax.random.normal(key, (m, d))
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), d, 3, 2)
    tables = build_tables(w, theta, 3, 2, 16)
    wb = bucketize_weights(w, tables)
    assert wb.shape == (2, 8, 16, d)
    ids = np.asarray(tables.table_ids)
    wbn = np.asarray(wb)
    wn = np.asarray(w)
    for t in (0, 1):
        for b in range(8):
            for s in range(16):
                nid = ids[t, b, s]
                if nid >= 0:
                    np.testing.assert_allclose(wbn[t, b, s], wn[nid])
                else:
                    assert np.all(wbn[t, b, s] == 0)


def test_load_stats():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (100, 8))
    theta = simhash.init_hyperplanes(jax.random.PRNGKey(1), 8, 2, 1)
    tables = build_tables(w, theta, 2, 1, 10)   # 4 buckets cap 10 -> drops
    stats = jax.tree.map(float, bucket_load_stats(tables))
    assert stats["overflow_frac"] > 0.3         # 100 into 40 slots
    assert stats["max_bucket_occupancy"] <= 10
